"""Serving driver on top of ``repro.engine``: continuous batching with a
paged, SP-sharded KV cache, compiled once per length bucket.

Every serve run — engine and ``--legacy`` alike — is described by a
``kind='decode'`` ``ExecutionPlan`` (the serving face: decode slots, page
size, paged-decode ``kernel_impl``), exactly like ``launch.train``: load a
persisted one with ``--plan``, or let ``make_serve_plan`` resolve the CLI
knobs (leave ``--c`` unset for the cost-model pick; ``--kernel`` defaults
to the backend: Pallas on TPU, the jnp reference on CPU). ``--save-plan``
persists the resolved plan for replay / CI artifacts.

CPU-runnable reduced mode (the default serves a mixed workload of
``--requests`` requests with staggered prompt lengths / budgets through the
engine and prints per-request generations + engine metrics):

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --smoke --devices 8 --c 1 --requests 8 --prompt-len 16 --gen 8

**Gateway mode** (``--replicas N`` and/or ``--prefix-cache``) serves the
workload through ``repro.gateway``: N engine replicas on disjoint device
submeshes (``--devices`` is the total; the plan records the per-replica
count), prefix-aware + load-aware routing with session affinity, and a
shared ``--system-prompt-len``-token prefix on every request so the
block-hash prefix cache has something to hit — per-request streams and the
gateway's hit-rate/eviction/routing metrics are printed:

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --smoke --devices 8 --replicas 2 --prefix-cache --requests 8

``--host-tier-bytes N`` adds the pinned-host KV tier under the prefix
cache (evictions spill, later hits reload — `engine.kv_connector`);
``--roles prefill,decode`` disaggregates the gateway into one engine per
role on disjoint submeshes, with finished prompts' KV handed from the
prefill replica to a decode replica through the connector:

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --smoke --devices 8 --roles prefill,decode --prefix-cache \
      --host-tier-bytes 268435456 --requests 8

``--legacy`` keeps the pre-engine static-batch greedy path (one fixed batch,
capacity-sized contiguous cache) — with the decode step compiled ONCE before
the token loop, not per token.

**HTTP front-end mode** (``--http``) starts the process-separated
``repro.frontend`` stack instead of running a canned workload: ``--workers``
engine processes (the device count is split evenly across them; ``0`` keeps
a single in-process replica), an async HTTP/SSE server streaming tokens
per request, priority classes (``--priority-classes``, highest first) with
optional per-class preemption (``--preempt``) and SLO-priced admission
(``--slo-ttft-ms``). SIGTERM drains gracefully: in-flight streams finish,
host-tier spills flush, workers join.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --smoke --devices 2 --workers 2 --http --port 8080 \
      --max-slots 2 --page-size 4 --max-len 64
"""

import argparse
import os


def _legacy_main(args, plan, cfg):
    """Static-batch greedy decode (pre-engine path, compile hoisted)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import ShapeConfig
    from repro.models.factory import build_model
    from repro.serve import kv_cache, step as serve_step

    model = build_model(cfg)
    run_cfg = plan.run_config()
    mesh = plan.build_mesh()
    sp = plan.sp_size

    capacity = args.prompt_len + args.gen
    capacity = ((capacity + sp - 1) // sp) * sp  # pad to SP multiple

    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, jnp.int32)

    # prefill at prompt length (its own SP-divisible length), then copy the
    # prefix of each shard-sharded cache into the capacity-sized cache
    shape_p = ShapeConfig("serve", seq_len=args.prompt_len,
                          global_batch=args.batch, kind="prefill")
    jprefill, _ = serve_step.build_prefill_step(model, mesh, run_cfg, shape_p)
    batch = {"tokens": tokens}
    if cfg.frontend_stub is not None:
        batch["frontend_emb"] = jnp.zeros(
            (args.batch, args.prompt_len, cfg.d_model),
            jnp.dtype(cfg.param_dtype))
    tok, cache_p = jprefill(params, batch)

    # expand attention caches to capacity (host-side, example-scale)
    cache = kv_cache.init_cache(cfg, args.batch, capacity)
    def merge(dst, src):
        out = {}
        for k in dst:
            if isinstance(dst[k], dict):
                out[k] = merge(dst[k], src[k])
            elif dst[k].ndim >= 3 and dst[k].shape[2] == capacity:
                pad = np.zeros(dst[k].shape, dst[k].dtype)
                pad[:, :, :src[k].shape[2]] = np.asarray(src[k])
                out[k] = jnp.asarray(pad)
            else:
                out[k] = src[k]
        return out
    cache = {"stack": merge(cache["stack"], cache_p["stack"])}

    # compile ONCE (static capacity-1 cache_len), then loop the executable
    shape_d = ShapeConfig("serve", seq_len=capacity,
                          global_batch=args.batch, kind="decode")
    jdecode, _ = serve_step.build_decode_step(model, mesh, run_cfg, shape_d)
    generated = [np.asarray(tok)]
    for _ in range(args.gen - 1):
        # NOTE example-scale: cache_len is static per compile; the engine
        # path passes per-sequence lengths as traced operands instead.
        tok, cache = jdecode(params, cache, tok)
        generated.append(np.asarray(tok))
    out = np.concatenate(generated, axis=1)
    print(f"[serve --legacy] prompt {tokens.shape} -> generated {out.shape}:")
    print(out)
    return out


def _engine_main(args, plan, cfg, registry=None, tracer=None):
    import numpy as np

    from repro.engine import Engine, EngineConfig, Request
    from repro.models.factory import build_model

    model = build_model(cfg)
    engine = Engine(model, plan,
                    EngineConfig(pages_per_shard=args.pages_per_shard,
                                 prefill_chunk=args.prefill_chunk),
                    registry=registry, tracer=tracer)
    rng = np.random.default_rng(args.seed)
    vocab = engine.cfg.vocab_size
    reqs = []
    for i in range(args.requests):
        # staggered mixed workload: prompts and budgets vary per request
        plen = max(1, args.prompt_len // 2 + (i * 3) % (args.prompt_len + 1))
        gen = max(1, args.gen // 2 + i % (args.gen + 1))
        reqs.append(Request(
            uid=f"req{i}", tokens=rng.integers(0, vocab, plen).tolist(),
            max_new_tokens=gen, temperature=args.temperature,
            top_k=args.top_k, top_p=args.top_p, seed=args.seed + i))
    for r in reqs:
        engine.add_request(r)
    out = engine.run()
    for r in reqs:
        print(f"[serve] {r.uid}: prompt_len={r.prompt_len} "
              f"-> {out[r.uid]}")
    stats = engine.metrics.to_dict()
    print("[serve] metrics: " + ", ".join(
        f"{k}={v:.3g}" if isinstance(v, float) else f"{k}={v}"
        for k, v in sorted(stats.items())))
    return out


def _gateway_main(args, plan, cfg, registry=None, tracer=None, plans=None):
    import numpy as np

    from repro.engine import EngineConfig, Request
    from repro.gateway import Gateway
    from repro.models.factory import build_model
    from repro.plan import cost as plan_cost

    model = build_model(cfg)
    gw = Gateway(model, plan,
                 EngineConfig(pages_per_shard=args.pages_per_shard,
                              prefill_chunk=args.prefill_chunk),
                 registry=registry, tracer=tracer, plans=plans)
    rng = np.random.default_rng(args.seed)
    vocab = cfg.vocab_size
    sys_len = args.system_prompt_len
    # two request families with distinct shared system prompts: prefix-aware
    # routing steers each family to the replica holding its pages, so with
    # --replicas 2 both replicas serve and both tries hit
    shared = [rng.integers(0, vocab, sys_len).tolist() if sys_len else []
              for _ in range(2)]
    reqs = []
    for i in range(args.requests):
        tail = max(1, args.prompt_len // 2 + (i * 3) % (args.prompt_len + 1))
        gen = max(1, args.gen // 2 + i % (args.gen + 1))
        reqs.append(Request(
            uid=f"req{i}",
            tokens=shared[i % 2] + rng.integers(0, vocab, tail).tolist(),
            max_new_tokens=gen, temperature=args.temperature,
            top_k=args.top_k, top_p=args.top_p, seed=args.seed + i))
    # each family is one "session" to exercise affinity too
    for i, r in enumerate(reqs):
        gw.add_request(r, session=f"sess{i % 2}" if sys_len else None)
        gw.step()           # stream as we go (prints drain incrementally)
    out = gw.run()
    for r in reqs:
        print(f"[gateway] {r.uid} (replica {gw._owner[r.uid]}): "
              f"prompt_len={r.prompt_len} -> {out[r.uid]}")
    stats = gw.stats()
    tier = stats.pop("host_tier")
    per = stats.pop("per_replica")
    print("[gateway] metrics: " + ", ".join(
        f"{k}={v:.3g}" if isinstance(v, float) else f"{k}={v}"
        for k, v in sorted(stats.items())))
    for i, m in enumerate(per):
        print(f"[gateway]   replica {i} ({gw.roles[i]}): "
              f"tokens={m['tokens_out']} "
              f"hit_rate={m['prefix_hit_rate']:.3g} "
              f"occupancy={m['occupancy']:.3g}")
    if tier["enabled"]:
        tier.pop("per_replica")
        print("[gateway] host tier: " + ", ".join(
            f"{k}={v:.3g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in sorted(tier.items())))
    if plan.prefix_cache and sys_len:
        roi = plan_cost.prefix_cache_value(
            cfg, prompt_len=sys_len + args.prompt_len, shared_len=sys_len,
            requests=max(args.requests // plan.replicas, 2),
            sp=plan.sp_size, page_size=plan.page_size,
            pages_per_shard=args.pages_per_shard, max_len=args.gen)
        print(f"[gateway] analytical cache value/replica: "
              f"hit_rate~{roi['hit_rate']:.2f} "
              f"saved_tokens~{roi['saved_tokens']} "
              f"cache_pages={roi['cache_pages']} fits={roi['fits']}")
    return out


def _frontend_main(args, plan, cfg, registry=None, tracer=None):
    """JetStream-style process-separated serving: spawn ``--workers``
    engine processes behind the orchestrator, serve HTTP/SSE until
    SIGTERM, then drain."""
    import dataclasses

    from repro.engine import EngineConfig
    from repro.frontend.orchestrator import Orchestrator
    from repro.frontend.protocol import make_worker_spec
    from repro.frontend.server import run_server
    from repro.frontend.slo import SLOAdmission, parse_classes
    from repro.frontend.worker import LocalReplica, ProcReplica

    # each worker is a single-engine replica of the per-worker plan
    spec = make_worker_spec(
        plan=dataclasses.replace(plan, replicas=1),
        eng=EngineConfig(pages_per_shard=args.pages_per_shard,
                         prefill_chunk=args.prefill_chunk),
        init_seed=0, trace=bool(args.trace_out))
    workers = max(args.workers, 0)
    if workers:
        print(f"[serve] spawning {workers} worker processes "
              f"({plan.n_devices} devices each)...", flush=True)
        replicas = [ProcReplica(i, spec) for i in range(workers)]
    else:
        print("[serve] --workers 0: single in-process replica", flush=True)
        replicas = [LocalReplica(0, spec)]
    classes = parse_classes(args.priority_classes,
                            slo_ttft_ms=args.slo_ttft_ms,
                            budget_tokens=args.class_budget_tokens)
    slo = None
    if args.slo_ttft_ms > 0:
        slo = SLOAdmission(cfg, sp=plan.sp_size, page_size=plan.page_size,
                           decode_batch=plan.decode_batch,
                           kernel=plan.kernel_impl,
                           calibration=args.slo_calibration)
    orch = Orchestrator(replicas, classes=classes, slo=slo,
                        preempt=bool(args.preempt), registry=registry,
                        tracer=tracer)
    run_server(orch, host=args.host, port=args.port, worker_spec=spec,
               workers=workers)
    return {}


def _resolve_plan(args):
    """Returns ``(plan, plans, cfg)`` — ``plans`` is the per-role list in
    disaggregated mode (``--roles`` or a multi-plan json), else None."""
    import json

    from repro.configs import registry
    from repro.plan import (ExecutionPlan, make_role_plans, make_serve_plan)

    def _cfg_for(plan):
        if not plan.arch or plan.arch not in registry.ASSIGNED_ARCHS:
            raise SystemExit(
                f"[serve] plan {args.plan} names unknown arch "
                f"{plan.arch!r}; known: {sorted(registry.ASSIGNED_ARCHS)}")
        # mesh_kind='local' plans are smoke runs (same convention as
        # launch.train); production plans carry the full config
        return (registry.get_smoke(plan.arch) if plan.mesh_kind == "local"
                else registry.get(plan.arch))

    if args.plan:
        rec = json.loads(open(args.plan).read())
        if "plans" in rec:                      # disaggregated role plans
            plans = [ExecutionPlan.from_dict(d) for d in rec["plans"]]
            plan = plans[0]
            print(f"[serve] loaded {len(plans)} role plans {args.plan}: "
                  f"roles={[p.role for p in plans]} "
                  f"host_tier={plan.host_tier_bytes}")
            return plan, plans, _cfg_for(plan)
        plan = ExecutionPlan.load(args.plan)
        print(f"[serve] loaded plan {args.plan}: scheme={plan.scheme} "
              f"C={plan.c} R={plan.r} kernel={plan.kernel_impl} "
              f"slots={plan.decode_batch} page={plan.page_size} "
              f"replicas={plan.replicas} prefix_cache={plan.prefix_cache} "
              f"host_tier={plan.host_tier_bytes}")
        return plan, None, _cfg_for(plan)
    import jax

    cfg = registry.get_smoke(args.arch) if args.smoke else registry.get(args.arch)
    # --smoke = forced-host/local mesh; otherwise the production mesh
    # (mesh_kind also encodes smoke-ness for --plan replay, as in
    # launch.train). With --replicas/--roles the plan's n_devices is the
    # per-replica share of the visible devices.
    if args.roles:
        roles = [r.strip() for r in args.roles.split(",") if r.strip()]
        n_dev = len(jax.devices()) // len(roles)
        plans = make_role_plans(
            cfg, roles=roles, n_devices=n_dev, arch=args.arch,
            data=args.data, c=args.c, decode_batch=args.max_slots,
            page_size=args.page_size, max_len=args.max_len,
            mesh_kind="local" if args.smoke else "production",
            kernel_impl=args.kernel, prefix_cache=bool(args.prefix_cache),
            host_tier_bytes=args.host_tier_bytes)
        return plans[0], plans, cfg
    replicas = max(args.replicas, 1)
    n_dev = len(jax.devices()) // replicas
    plan = make_serve_plan(
        cfg, arch=args.arch, n_devices=n_dev, data=args.data,
        c=args.c, decode_batch=args.max_slots, page_size=args.page_size,
        max_len=args.max_len, mesh_kind="local" if args.smoke
        else "production", kernel_impl=args.kernel,
        replicas=replicas, prefix_cache=bool(args.prefix_cache),
        host_tier_bytes=args.host_tier_bytes)
    return plan, None, cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (optional with --plan)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--c", type=int, default=None,
                    help="StarTrail C (default: cost-model pick)")
    ap.add_argument("--plan", default=None,
                    help="load a persisted serve ExecutionPlan json")
    ap.add_argument("--save-plan", default=None,
                    help="persist the resolved serve plan to this path")
    ap.add_argument("--kernel", default=None, choices=["ref", "pallas"],
                    help="paged-decode kernel (default: backend pick — "
                         "pallas on TPU, ref on CPU)")
    ap.add_argument("--legacy", action="store_true",
                    help="pre-engine static-batch greedy path")
    ap.add_argument("--batch", type=int, default=2, help="legacy batch size")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    # engine knobs
    ap.add_argument("--requests", type=int, default=8)
    # gateway knobs
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas (gateway mode when > 1); "
                         "--devices is split evenly across them")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="block-hash prefix cache with COW page reuse "
                         "(gateway mode)")
    ap.add_argument("--host-tier-bytes", type=int, default=0,
                    help="pinned-host KV tier capacity per engine, bytes "
                         "(0 = off; prefix-cache evictions spill here and "
                         "later trie hits reload instead of re-prefilling; "
                         "needs --prefix-cache)")
    ap.add_argument("--roles", default=None,
                    help="comma-separated replica roles for disaggregated "
                         "serving, e.g. 'prefill,decode' — one engine per "
                         "role on disjoint submeshes; overrides --replicas")
    ap.add_argument("--system-prompt-len", type=int, default=32,
                    help="shared prompt prefix length in gateway mode "
                         "(0 = fully independent prompts)")
    # HTTP front-end knobs (repro.frontend; --http switches modes)
    ap.add_argument("--http", action="store_true",
                    help="serve an async HTTP/SSE front end "
                         "(repro.frontend) instead of a canned workload")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--workers", type=int, default=0,
                    help="engine worker *processes* behind the front end "
                         "(--devices is split evenly across them; 0 = one "
                         "in-process replica)")
    ap.add_argument("--priority-classes", default="interactive,batch",
                    help="comma-separated priority classes, highest "
                         "first; classes after the first are preemptible")
    ap.add_argument("--slo-ttft-ms", type=float, default=0.0,
                    help="TTFT SLO for the highest class, priced at "
                         "admission from plan.cost (0 = no SLO gate)")
    ap.add_argument("--class-budget-tokens", type=int, default=0,
                    help="outstanding-token budget for the highest class "
                         "(0 = unlimited)")
    ap.add_argument("--slo-calibration", type=float, default=1.0,
                    help="scale analytical seconds to this machine "
                         "(measured_step_s / analytical_step_s)")
    ap.add_argument("--preempt", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="spill the worst preemptible stream when a "
                         "higher-priority request is stuck queued")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--pages-per-shard", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="split long prompts into ~this many tokens per "
                         "driver step (rounded up to a compile bucket), "
                         "interleaved with decode; 0 = monolithic prefill")
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    # observability (repro.obs; all off by default = near-zero overhead)
    ap.add_argument("--metrics-dump", default=None,
                    help="write the obs registry here after the run "
                         "(Prometheus text; .json suffix -> JSON dump)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace-format JSON timeline of "
                         "request/engine/gateway spans here")
    ap.add_argument("--comm-report", default=None,
                    help="compile the plan's attention island, parse its "
                         "HLO collectives, and write the measured-vs-"
                         "analytical comm-volume report (JSON) here")
    args = ap.parse_args(argv)
    if not args.plan and not args.arch:
        ap.error("--arch is required (unless --plan carries it)")
    if args.http and args.workers > 1:
        # the device count is split across worker processes exactly like
        # gateway replicas; the resolved plan is then per worker
        args.replicas = args.workers

    if args.plan and not args.devices:
        # a local-mesh plan records its forced-host device count; read it
        # from the raw json (before anything can initialise the backend).
        # n_devices is per replica — the gateway needs the product.
        import json

        rec = json.loads(open(args.plan).read())
        if "plans" in rec:                      # disaggregated role plans
            if rec["plans"] and rec["plans"][0].get("mesh_kind") == "local":
                args.devices = sum(int(d["n_devices"]) for d in rec["plans"])
        else:
            rec = rec.get("plan", rec)
            if rec.get("mesh_kind") == "local":
                args.devices = \
                    int(rec["n_devices"]) * int(rec.get("replicas", 1))
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    plan, plans, cfg = _resolve_plan(args)
    print(f"[serve] plan: P_sp={plan.sp_size} scheme={plan.scheme} "
          f"C={plan.c} R={plan.r} data={plan.data} "
          f"kernel={plan.kernel_impl} slots={plan.decode_batch} "
          f"page={plan.page_size} capacity={plan.seq_len} "
          f"replicas={len(plans) if plans else plan.replicas} "
          f"roles={[p.role for p in plans] if plans else [plan.role]} "
          f"prefix_cache={plan.prefix_cache} "
          f"host_tier={plan.host_tier_bytes}")
    if args.save_plan:
        if plans:
            import json as _json
            import pathlib

            path = pathlib.Path(args.save_plan)
            path.parent.mkdir(parents=True, exist_ok=True)
            path.write_text(_json.dumps(
                {"plans": [p.to_dict() for p in plans]}, indent=2))
        else:
            path = plan.save(args.save_plan)
        print(f"[serve] plan saved -> {path}")

    from repro import obs

    registry = obs.Registry()
    tracer = obs.Tracer(enabled=bool(args.trace_out))
    if args.http:
        out = _frontend_main(args, plan, cfg, registry=registry,
                             tracer=tracer)
    elif args.legacy:
        out = _legacy_main(args, plan, cfg)
    elif plans or plan.replicas > 1 or plan.prefix_cache:
        out = _gateway_main(args, plan, cfg, registry=registry,
                            tracer=tracer, plans=plans)
    else:
        out = _engine_main(args, plan, cfg, registry=registry,
                           tracer=tracer)

    if args.metrics_dump:
        fmt = "json" if args.metrics_dump.endswith(".json") else "prometheus"
        registry.dump(args.metrics_dump, fmt=fmt)
        print(f"[serve] metrics dump -> {args.metrics_dump} ({fmt})")
    if args.trace_out:
        tracer.dump(args.trace_out)
        print(f"[serve] trace ({len(tracer.events())} events) -> "
              f"{args.trace_out}")
    if args.comm_report:
        from repro.obs import commlog

        rep = commlog.comm_report(cfg, plan)
        commlog.dump_report(rep, args.comm_report)
        ratios = {k: v["ratio"] for k, v in rep["per_collective"].items()}
        print(f"[serve] comm report -> {args.comm_report} "
              f"within_tolerance={rep['within_tolerance']} ratios={ratios}")
    return out


if __name__ == "__main__":
    main()
