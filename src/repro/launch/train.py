"""End-to-end training driver.

On real hardware this runs the production mesh; on CPU use --devices to
force host devices and a reduced config for a real multi-step run:

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --smoke --devices 8 --data 2 --c 1 --steps 20
"""

import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU-runnable)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU)")
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--c", type=int, default=1)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="default")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax

    from repro.configs import registry
    from repro.configs.base import SHAPES, RunConfig, ShapeConfig
    from repro.dist import meshes
    from repro.launch.mesh import make_production_mesh
    from repro.models.factory import build_model
    from repro.optim import adamw
    from repro.train import trainer as trainer_lib

    if args.smoke:
        cfg = registry.get_smoke(args.arch)
        shape = ShapeConfig("smoke", seq_len=args.seq_len,
                            global_batch=args.batch, kind="train")
        r = args.devices // (args.data * args.c * args.c)
        mesh = meshes.local_mesh_for_tests(c=args.c, r=r, data=args.data)
    else:
        cfg = registry.get(args.arch)
        shape = SHAPES[args.shape]
        prod = make_production_mesh(multi_pod=args.multi_pod)
        mesh = meshes.refine_mesh(prod, c=args.c)

    model = build_model(cfg)
    run_cfg = RunConfig(c=args.c, multi_pod=args.multi_pod,
                        sharding_rules=args.rules)
    adam_cfg = adamw.AdamWConfig(learning_rate=args.lr, warmup_steps=5,
                                 decay_steps=max(args.steps, 10),
                                 state_dtype=cfg.opt_dtype)
    tcfg = trainer_lib.TrainerConfig(
        num_steps=args.steps, ckpt_every=max(args.steps // 2, 1),
        ckpt_dir=args.ckpt_dir, metrics_path=args.metrics, log_every=5)
    metrics = trainer_lib.train(model, mesh, run_cfg, shape, adam_cfg, tcfg)
    print(f"[train] done: {metrics}")
    return metrics


if __name__ == "__main__":
    main()
