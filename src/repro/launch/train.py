"""End-to-end training driver.

Every run is described by an ``repro.plan.ExecutionPlan``: either loaded
from a plan file (``--plan results/PLAN_<arch>_<shape>.json``), autotuned
on the spot (``--autotune``: measure the analytical top-k arrangements,
persist + use the winner), or resolved from the CLI knobs by the analytical
cost model (leave ``--c``/``--scheme`` unset to let the model pick).

On real hardware this runs the production mesh; on CPU use --devices to
force host devices and a reduced config for a real multi-step run:

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --smoke --devices 8 --data 2 --c 1 --steps 20
"""

import argparse
import os


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (optional with --plan)")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU-runnable)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU)")
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--c", type=int, default=None,
                    help="StarTrail C (default: cost-model pick)")
    ap.add_argument("--scheme", default=None,
                    choices=["startrail", "ring", "ulysses"],
                    help="attention scheme (default: cost-model pick)")
    ap.add_argument("--placement", default=None,
                    choices=["team_inner", "ring_inner"])
    ap.add_argument("--microbatches", type=int, default=None,
                    help="grad-accumulation microbatches (default: plan)")
    ap.add_argument("--comm-chunks", type=int, default=None,
                    help="ring-transfer sub-chunks (default: overlap-model "
                         "pick; must divide the team seq length C*N/P)")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="disable the double-buffered ring scan (debug A/B;"
                         " bit-identical either way)")
    ap.add_argument("--plan", default=None,
                    help="load a persisted ExecutionPlan json")
    ap.add_argument("--autotune", action="store_true",
                    help="measure the analytical top-k and use the winner")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="default")
    # observability (repro.obs; all off by default = near-zero overhead)
    ap.add_argument("--metrics-dump", default=None,
                    help="write the obs registry (incl. per-collective "
                         "comm_bytes_total from the plan's arrangement) "
                         "here after the run (Prometheus text; .json "
                         "suffix -> JSON dump)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace-format JSON timeline of "
                         "train/data, train/step and train/ckpt spans here")
    args = ap.parse_args(argv)
    if not args.plan and not args.arch:
        ap.error("--arch is required (unless --plan carries it)")

    if args.plan and not args.devices:
        # a local-mesh plan records its forced-host device count; read it
        # from the raw json (before anything can initialise the backend)
        import json

        rec = json.loads(open(args.plan).read())
        rec = rec.get("plan", rec)
        if rec.get("mesh_kind") == "local":
            args.devices = int(rec["n_devices"])
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    from repro.configs import registry
    from repro.configs.base import SHAPES, ShapeConfig
    from repro.models.factory import build_model
    from repro.optim import adamw
    from repro.plan import ExecutionPlan, autotune as autotune_lib, make_plan
    from repro.train import trainer as trainer_lib

    if args.plan:
        plan = ExecutionPlan.load(args.plan)
        cfg = (registry.get_smoke(plan.arch) if plan.mesh_kind == "local"
               else registry.get(plan.arch))
        print(f"[train] loaded plan {args.plan}: scheme={plan.scheme} "
              f"C={plan.c} R={plan.r} microbatches={plan.microbatches}")
    else:
        if args.smoke:
            cfg = registry.get_smoke(args.arch)
            shape = ShapeConfig("smoke", seq_len=args.seq_len,
                                global_batch=args.batch, kind="train")
            n_devices, data, pod, mesh_kind = (args.devices, args.data, 1,
                                               "local")
        else:
            cfg = registry.get(args.arch)
            shape = SHAPES[args.shape]
            pod = 2 if args.multi_pod else 1
            n_devices, data, mesh_kind = 256 * pod, 16, "production"
        if args.autotune:
            tuned = autotune_lib.autotune(
                cfg, shape, arch=args.arch, n_devices=n_devices, data=data,
                mesh_kind=mesh_kind, microbatches=args.microbatches)
            plan = tuned["plan"]
            print(f"[train] autotuned plan -> {tuned['path']}: "
                  f"scheme={plan.scheme} C={plan.c} R={plan.r}")
        else:
            plan = make_plan(
                cfg, shape, arch=args.arch, n_devices=n_devices, data=data,
                pod=pod, scheme=args.scheme, c=args.c,
                placement=args.placement, microbatches=args.microbatches,
                mesh_kind=mesh_kind, sharding_rules=args.rules,
                pipeline_scan=not args.no_pipeline,
                comm_chunks=args.comm_chunks)
    print(f"[train] plan: P_sp={plan.sp_size} scheme={plan.scheme} "
          f"C={plan.c} R={plan.r} data={plan.data} "
          f"microbatches={plan.microbatches}")

    model = build_model(cfg)
    adam_cfg = adamw.AdamWConfig(learning_rate=args.lr, warmup_steps=5,
                                 decay_steps=max(args.steps, 10),
                                 state_dtype=cfg.opt_dtype)
    tcfg = trainer_lib.TrainerConfig(
        num_steps=args.steps, ckpt_every=max(args.steps // 2, 1),
        ckpt_dir=args.ckpt_dir, metrics_path=args.metrics, log_every=5)

    from repro import obs

    obs_registry = obs.Registry() if args.metrics_dump else None
    # annotate=True wraps each host span in jax.profiler.TraceAnnotation,
    # so train/step lines up with the in-graph ring_permute_issue /
    # ring_block_compute scopes when a device profile is captured alongside
    tracer = (obs.Tracer(enabled=True, annotate=True)
              if args.trace_out else None)
    metrics = trainer_lib.train(model, plan, adam_cfg, tcfg,
                                tracer=tracer, registry=obs_registry)
    if args.metrics_dump:
        fmt = "json" if args.metrics_dump.endswith(".json") else "prometheus"
        obs_registry.dump(args.metrics_dump, fmt=fmt)
        print(f"[train] metrics dump -> {args.metrics_dump} ({fmt})")
    if args.trace_out:
        tracer.dump(args.trace_out)
        print(f"[train] trace ({len(tracer.events())} events) -> "
              f"{args.trace_out}")
    print(f"[train] done: {metrics}")
    return metrics


if __name__ == "__main__":
    main()
