import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
the full production step (train: fwd+bwd+AdamW; prefill / decode: serve
step) is lowered with ShapeDtypeStruct stand-ins (zero allocation) onto the
16x16 single-pod mesh and the 2x16x16 multi-pod mesh, compiled, and the
compiled artifact's memory/cost analyses + collective schedule are recorded
for the roofline analysis (results/dryrun/*.json).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--c 2]
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import SHAPES, RunConfig
from repro.dist import meshes
from repro.launch.mesh import make_production_mesh
from repro.models.factory import build_model
from repro.optim import adamw
from repro.roofline import hlo as hlo_lib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _compile_once(model, mesh, run_cfg, shape, cfg):
    """Lower + compile the step for this shape kind; returns (lowered, compiled)."""
    from repro.serve import kv_cache, step as serve_step
    from repro.train import step as train_step

    if shape.kind == "train":
        acfg = adamw.AdamWConfig(state_dtype=cfg.opt_dtype)
        jstep, _ = train_step.build_train_step(model, mesh, run_cfg, shape,
                                               acfg)
        params = model.abstract()
        opt = adamw.abstract_state(params, acfg)
        batch = model.input_specs(shape)
        lowered = jstep.lower(params, opt, batch)
    elif shape.kind == "prefill":
        jstep, _ = serve_step.build_prefill_step(model, mesh, run_cfg, shape)
        params = model.abstract()
        batch = {k: v for k, v in model.input_specs(shape).items()
                 if k != "labels"}
        if cfg.encdec and "frontend_emb" not in batch:
            batch["frontend_emb"] = jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len, cfg.d_model),
                jnp.dtype(cfg.param_dtype))
        lowered = jstep.lower(params, batch)
    else:  # decode
        jstep, _ = serve_step.build_decode_step(model, mesh, run_cfg, shape)
        params = model.abstract()
        cache = kv_cache.cache_spec(cfg, shape.global_batch, shape.seq_len)
        tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        lowered = jstep.lower(params, cache, tokens)
    return lowered, lowered.compile()


def _costs(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = hlo_lib.collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_total": float(coll["total_bytes"]),
        "coll_by_kind": coll["bytes_by_kind"],
        "coll_counts": coll["count_by_kind"],
    }


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               c: int = 2, rules: str = "default", remat: str = "attn_out",
               placement: str = "team_inner"):
    """Lower + compile one cell; exact cost accounting via two-point depth
    extrapolation.

    XLA's cost_analysis counts while-loop bodies once (not x trip count),
    so the full-depth compile proves compile/memory while per-step costs
    come from two shallow compiles (1 and 2 layer-periods) with all inner
    scans (rings, vocab-CE chunks) unrolled:

        cost(L) = cost(1) + (cost(2) - cost(1)) * (n_periods - 1)

    which is exact for homogeneous periods (true by construction).
    """
    import dataclasses as dc

    from repro.models import transformer

    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    ok, why = registry.shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    prod = make_production_mesh(multi_pod=multi_pod)
    mesh = meshes.refine_mesh(prod, c=c, placement=placement)
    run_cfg = RunConfig(c=c, multi_pod=multi_pod, sharding_rules=rules,
                        remat=remat)

    # ---- full-depth compile: proves the cell + memory analysis ----
    model = build_model(cfg)
    t0 = time.time()
    lowered, compiled = _compile_once(model, mesh, run_cfg, shape, cfg)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    raw = _costs(compiled)

    # ---- shallow unrolled compiles for exact per-step costs ----
    period = len(transformer.layer_pattern(cfg))
    n_periods = cfg.num_layers // period
    run_u = dc.replace(run_cfg, unroll_scans=True)
    shallow = []
    for k in (1, 2):
        kcfg = dc.replace(cfg, num_layers=k * period)
        if cfg.encdec:
            kcfg = dc.replace(kcfg, num_encoder_layers=k)
        _, comp_k = _compile_once(build_model(kcfg), mesh, run_u, shape, kcfg)
        shallow.append(_costs(comp_k))
    c1, c2 = shallow

    def extrap(key):
        return c1[key] + (c2[key] - c1[key]) * (n_periods - 1)

    coll_by_kind = {}
    for kind in set(c1["coll_by_kind"]) | set(c2["coll_by_kind"]):
        a = c1["coll_by_kind"].get(kind, 0)
        b = c2["coll_by_kind"].get(kind, 0)
        coll_by_kind[kind] = a + (b - a) * (n_periods - 1)

    n_dev = 512 if multi_pod else 256
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "c": c,
        "rules": rules,
        "remat": remat,
        "placement": placement,
        "devices": n_dev,
        "n_periods": n_periods,
        "compile_s": round(t_compile, 1),
        "flops_per_device": extrap("flops"),
        "bytes_accessed_per_device": extrap("bytes"),
        "collectives": {
            "total_bytes": extrap("coll_total"),
            "bytes_by_kind": coll_by_kind,
            "count_by_kind_one_period": c1["coll_counts"],
        },
        "raw_full_depth": raw,
        "shallow": {"k1": c1, "k2": c2},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
        },
    }
    return rec


def run_and_save(arch, shape_name, **kw):
    RESULTS.mkdir(parents=True, exist_ok=True)
    tag = "multi" if kw.get("multi_pod") else "single"
    c = kw.get("c", 2)
    name = f"{arch}__{shape_name}__{tag}__c{c}"
    if kw.get("rules", "default") != "default":
        name += f"__{kw['rules']}"
    if kw.get("placement", "team_inner") != "team_inner":
        name += f"__{kw['placement']}"
    if kw.get("remat", "attn_out") != "attn_out":
        name += f"__remat_{kw['remat']}"
    out = RESULTS / f"{name}.json"
    try:
        rec = lower_cell(arch, shape_name, **kw)
        rec["status"] = "skipped" if rec.get("skipped") else "ok"
    except Exception as e:  # noqa: BLE001
        rec = {"arch": arch, "shape": shape_name, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:], **kw}
    out.write_text(json.dumps(rec, indent=2))
    status = rec["status"]
    extra = ""
    if status == "ok":
        gb = rec["memory"]["peak_bytes_per_device"] / 2**30
        extra = (f" peak={gb:.2f}GiB/dev flops={rec['flops_per_device']:.3g}"
                 f" compile={rec['compile_s']}s")
    print(f"[{status}] {name}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--c", type=int, default=2)
    ap.add_argument("--rules", default="default")
    ap.add_argument("--remat", default="attn_out")
    ap.add_argument("--placement", default="team_inner")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in registry.ASSIGNED_ARCHS:
            for sname in SHAPES:
                cells.append((a, sname))
    else:
        cells.append((args.arch, args.shape))

    meshes_to_run = [args.multi_pod]
    if args.both_meshes:
        meshes_to_run = [False, True]

    n_bad = 0
    for mp in meshes_to_run:
        for a, sname in cells:
            rec = run_and_save(a, sname, multi_pod=mp, c=args.c,
                               rules=args.rules, remat=args.remat,
                               placement=args.placement)
            if rec.get("status") == "error":
                n_bad += 1
    if n_bad:
        raise SystemExit(f"{n_bad} cells failed")


if __name__ == "__main__":
    main()
