import os
import sys

if "--autotune" in sys.argv:
    # the autotune path measures real steps on the CPU smoke mesh
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
else:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
the full production step (train: fwd+bwd+AdamW; prefill / decode: serve
step) is lowered with ShapeDtypeStruct stand-ins (zero allocation) onto the
16x16 single-pod mesh and the 2x16x16 multi-pod mesh, compiled, and the
compiled artifact's memory/cost analyses + collective schedule are recorded
for the roofline analysis (results/dryrun/*.json). Each cell is described
by an ``repro.plan.ExecutionPlan`` (``--plan FILE`` replays a persisted
one).

``--autotune`` instead runs the measured arrangement search on the 8-device
CPU smoke mesh (short jitted steps over the analytical top-k plus the
analytical worst), persists the winner to ``results/PLAN_<arch>_smoke.json``
and fails if the chosen plan does not beat the worst candidate — the CI
`plan-smoke` job runs exactly this.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--c 2]
  PYTHONPATH=src python -m repro.launch.dryrun --autotune [--arch ...]
"""

import argparse
import dataclasses as dc
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.models.factory import build_model
from repro.optim import adamw
from repro.plan import ExecutionPlan, make_plan
from repro.roofline import hlo as hlo_lib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _compile_once(model, mesh, run_cfg, shape, cfg):
    """Lower + compile the step for this shape kind; returns (lowered, compiled)."""
    from repro.serve import kv_cache, step as serve_step
    from repro.train import step as train_step

    if shape.kind == "train":
        acfg = adamw.AdamWConfig(state_dtype=cfg.opt_dtype)
        jstep, _ = train_step.build_train_step(model, mesh, run_cfg, shape,
                                               acfg)
        params = model.abstract()
        opt = adamw.abstract_state(params, acfg)
        batch = model.input_specs(shape)
        lowered = jstep.lower(params, opt, batch)
    elif shape.kind == "prefill":
        jstep, _ = serve_step.build_prefill_step(model, mesh, run_cfg, shape)
        params = model.abstract()
        batch = {k: v for k, v in model.input_specs(shape).items()
                 if k != "labels"}
        if cfg.encdec and "frontend_emb" not in batch:
            batch["frontend_emb"] = jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len, cfg.d_model),
                jnp.dtype(cfg.param_dtype))
        lowered = jstep.lower(params, batch)
    else:  # decode
        jstep, _ = serve_step.build_decode_step(model, mesh, run_cfg, shape)
        params = model.abstract()
        cache = kv_cache.cache_spec(cfg, shape.global_batch, shape.seq_len)
        tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        lowered = jstep.lower(params, cache, tokens)
    return lowered, lowered.compile()


def _costs(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = hlo_lib.collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_total": float(coll["total_bytes"]),
        "coll_by_kind": coll["bytes_by_kind"],
        "coll_counts": coll["count_by_kind"],
    }


def plan_for_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                  c: int = 2, rules: str = "default",
                  remat: str = "attn_out",
                  placement: str = "team_inner") -> ExecutionPlan:
    """The production ExecutionPlan for one dry-run cell."""
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    pod = 2 if multi_pod else 1
    # microbatches resolved by the plan (auto for production train shapes:
    # this is what lets train_4k's global_batch=256 compile honestly)
    return make_plan(cfg, shape, arch=arch, n_devices=256 * pod, data=16,
                     pod=pod, c=c, placement=placement, remat=remat,
                     sharding_rules=rules, mesh_kind="production")


def lower_cell(arch: str, shape_name: str, **plan_kw):
    """Lower + compile one cell; exact cost accounting via two-point depth
    extrapolation.

    XLA's cost_analysis counts while-loop bodies once (not x trip count),
    so the full-depth compile proves compile/memory while per-step costs
    come from two shallow compiles (1 and 2 layer-periods) with all inner
    scans (rings, vocab-CE chunks) unrolled:

        cost(L) = cost(1) + (cost(2) - cost(1)) * (n_periods - 1)

    which is exact for homogeneous periods (true by construction).
    """
    from repro.models import transformer

    plan = plan_kw.pop("plan", None)
    if plan is not None:
        # replay: the plan carries the shape (incl. non-registry ones like
        # 'smoke') and whether it was tuned on the reduced config
        cfg = (registry.get_smoke(arch) if plan.mesh_kind == "local"
               else registry.get(arch))
        shape = plan.shape_config()
    else:
        cfg = registry.get(arch)
        shape = SHAPES[shape_name]
    ok, why = registry.shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    if plan is None:
        plan = plan_for_cell(arch, shape_name, **plan_kw)
    mesh = plan.build_mesh()
    run_cfg = plan.run_config()

    # ---- full-depth compile: proves the cell + memory analysis ----
    model = build_model(cfg)
    t0 = time.time()
    lowered, compiled = _compile_once(model, mesh, run_cfg, shape, cfg)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    raw = _costs(compiled)

    # ---- shallow unrolled compiles for exact per-step costs ----
    period = len(transformer.layer_pattern(cfg))
    n_periods = cfg.num_layers // period
    run_u = dc.replace(run_cfg, unroll_scans=True)
    shallow = []
    for k in (1, 2):
        kcfg = dc.replace(cfg, num_layers=k * period)
        if cfg.encdec:
            kcfg = dc.replace(kcfg, num_encoder_layers=k)
        _, comp_k = _compile_once(build_model(kcfg), mesh, run_u, shape, kcfg)
        shallow.append(_costs(comp_k))
    c1, c2 = shallow

    def extrap(key):
        return c1[key] + (c2[key] - c1[key]) * (n_periods - 1)

    coll_by_kind = {}
    for kind in set(c1["coll_by_kind"]) | set(c2["coll_by_kind"]):
        a = c1["coll_by_kind"].get(kind, 0)
        b = c2["coll_by_kind"].get(kind, 0)
        coll_by_kind[kind] = a + (b - a) * (n_periods - 1)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if plan.pod > 1 else "16x16",
        "kind": shape.kind,
        "plan": plan.to_dict(),
        "c": plan.c,
        "rules": plan.sharding_rules,
        "remat": plan.remat,
        "placement": plan.placement,
        "devices": plan.n_devices,
        "n_periods": n_periods,
        "compile_s": round(t_compile, 1),
        "flops_per_device": extrap("flops"),
        "bytes_accessed_per_device": extrap("bytes"),
        "collectives": {
            "total_bytes": extrap("coll_total"),
            "bytes_by_kind": coll_by_kind,
            "count_by_kind_one_period": c1["coll_counts"],
        },
        "raw_full_depth": raw,
        "shallow": {"k1": c1, "k2": c2},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
        },
    }
    return rec


def run_and_save(arch, shape_name, **kw):
    RESULTS.mkdir(parents=True, exist_ok=True)
    tag = "multi" if kw.get("multi_pod") else "single"
    c = kw.get("c", 2)
    name = f"{arch}__{shape_name}__{tag}__c{c}"
    if kw.get("rules", "default") != "default":
        name += f"__{kw['rules']}"
    if kw.get("placement", "team_inner") != "team_inner":
        name += f"__{kw['placement']}"
    if kw.get("remat", "attn_out") != "attn_out":
        name += f"__remat_{kw['remat']}"
    out = RESULTS / f"{name}.json"
    try:
        rec = lower_cell(arch, shape_name, **kw)
        rec["status"] = "skipped" if rec.get("skipped") else "ok"
    except Exception as e:  # noqa: BLE001
        kw_rec = {k: (v.to_dict() if isinstance(v, ExecutionPlan) else v)
                  for k, v in kw.items()}
        rec = {"arch": arch, "shape": shape_name, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:], **kw_rec}
    out.write_text(json.dumps(rec, indent=2))
    status = rec["status"]
    extra = ""
    if status == "ok":
        gb = rec["memory"]["peak_bytes_per_device"] / 2**30
        extra = (f" peak={gb:.2f}GiB/dev flops={rec['flops_per_device']:.3g}"
                 f" compile={rec['compile_s']}s")
    print(f"[{status}] {name}{extra}", flush=True)
    return rec


def run_autotune(arch: str, *, seq_len: int = 64, batch: int = 4,
                 data: int = 1, steps: int = 3):
    """Measured arrangement search on the CPU smoke mesh (CI `plan-smoke`).

    Fails (SystemExit) unless the chosen plan beats the worst measured
    candidate — i.e. the tuner must never hand back the slowest
    arrangement of the ones it timed.
    """
    from repro.configs.base import ShapeConfig
    from repro.plan import autotune as autotune_lib

    cfg = registry.get_smoke(arch)
    n_devices = jax.device_count()
    shape = ShapeConfig("smoke", seq_len=seq_len, global_batch=batch,
                        kind="train")
    out = autotune_lib.autotune(cfg, shape, arch=arch, n_devices=n_devices,
                                data=data, mesh_kind="local", steps=steps)
    for e in out["measured"]:
        print(f"[autotune] {e['arrangement'].key:24s} "
              f"measured={e['measured_s'] * 1e3:8.2f}ms "
              f"analytical={e['analytical_s'] * 1e6:8.1f}us", flush=True)
    best, worst = out["measured"][0], out["measured"][-1]
    print(f"[autotune] winner={best['arrangement'].key} -> {out['path']}")
    # the in-memory winner is measured-best by construction, so assert the
    # things that can actually break: the *persisted* plan must round-trip
    # to that winner, and it must strictly beat the analytical-worst anchor
    # (a tie means the timing harness degenerated)
    if ExecutionPlan.load(out["path"]) != best["plan"]:
        raise SystemExit("persisted plan is not the measured winner")
    if len(out["measured"]) > 1 and \
            not best["measured_s"] < worst["measured_s"]:
        raise SystemExit(
            "autotuned pick does not beat the worst measured candidate "
            f"({best['measured_s']:.6f}s vs {worst['measured_s']:.6f}s)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--c", type=int, default=2)
    ap.add_argument("--rules", default="default")
    ap.add_argument("--remat", default="attn_out")
    ap.add_argument("--placement", default="team_inner")
    ap.add_argument("--plan", default=None,
                    help="replay a persisted ExecutionPlan json for the cell")
    ap.add_argument("--autotune", action="store_true",
                    help="measured arrangement search on the CPU smoke mesh")
    args = ap.parse_args()

    if args.autotune:
        run_autotune(args.arch or "h2o-danube-1.8b")
        return

    plan = ExecutionPlan.load(args.plan) if args.plan else None
    cells = []
    if args.all:
        for a in registry.ASSIGNED_ARCHS:
            for sname in SHAPES:
                cells.append((a, sname))
    else:
        cells.append((args.arch or (plan and plan.arch),
                      args.shape or (plan and plan.shape)))

    meshes_to_run = [args.multi_pod]
    if args.both_meshes:
        meshes_to_run = [False, True]

    n_bad = 0
    for mp in meshes_to_run:
        for a, sname in cells:
            kw = dict(plan=plan) if plan else dict(
                multi_pod=mp, c=args.c, rules=args.rules, remat=args.remat,
                placement=args.placement)
            rec = run_and_save(a, sname, **kw)
            if rec.get("status") == "error":
                n_bad += 1
    if n_bad:
        raise SystemExit(f"{n_bad} cells failed")


if __name__ == "__main__":
    main()
