"""Production mesh definition.

``make_production_mesh`` builds the mandated device grid (a function, not a
module-level constant, so importing this module never touches jax device
state), derived from ``jax.device_count()`` with the (16, 16) single-pod /
(2, 16, 16) multi-pod shapes as the default target. The plan layer
(``repro.plan``) is the only consumer: it refines the trailing 'model' axis
into the StarTrail (sp_grp, sp_ring, sp_team) structure via
``repro.dist.meshes.refine_mesh``.

When the available device count cannot host the target grid the error lists
every legal refinable (data, model) factorisation of the actual count
instead of letting jax fail with a silent shape mismatch.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

DEFAULT_GRID = (16, 16)              # (data, model)
DEFAULT_GRID_MULTI_POD = (2, 16, 16)  # (pod, data, model)


def refinable_grids(n_devices: int) -> List[Tuple[int, int]]:
    """Legal (data, model) grids for `n_devices`: model must admit a C >= 2
    StarTrail refinement (model % 4 == 0, so (C=2, R=model/4) exists)."""
    out = []
    for model in range(4, n_devices + 1, 4):
        if n_devices % model == 0:
            out.append((n_devices // model, model))
    return out


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = DEFAULT_GRID_MULTI_POD if multi_pod else DEFAULT_GRID
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    n = jax.device_count()
    if n < need:
        legal = refinable_grids(n)
        hint = (f"legal refinable (data, model) grids for {n} device(s): "
                f"{legal}" if legal else
                f"{n} device(s) admit no C>=2-refinable grid (need model % 4"
                f" == 0)")
        raise ValueError(
            f"production mesh {'x'.join(map(str, shape))} needs {need} "
            f"devices but only {n} are available; {hint}. For CPU runs use "
            f"--smoke with --devices N (forced host devices) instead.")
    # jax.make_mesh keeps the topology-aware device assignment (axes map
    # to physically-adjacent devices — the placement tuning depends on it)
    return jax.make_mesh(shape, axes)
