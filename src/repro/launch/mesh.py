"""Production mesh definition.

``make_production_mesh`` builds the mandated device grid (a function, not a
module-level constant, so importing this module never touches jax device
state). The framework refines its 'model' axis into the StarTrail
(sp_grp, sp_ring, sp_team) structure via ``repro.dist.meshes.refine_mesh``.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
